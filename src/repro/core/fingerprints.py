"""Packed binary molecular fingerprints and bit-vector similarity metrics.

Fingerprints are L-bit binary vectors (paper: L=1024, Morgan radius-2).
We store them packed little-endian into ``uint32`` words, shape (..., L//32),
so a database of N molecules is an ``(N, 32)`` uint32 array for L=1024.
All similarity math runs on packed words via ``lax.population_count`` —
the TPU-native analogue of the paper's BitCnt LUT tree (DESIGN.md §2).

Every supported metric is a rational function of the same popcount triple
``(a=|A|, b=|B|, c=|A∩B|)`` the kernels already compute, so metric choice
is a trace-time parameter (:class:`Metric`): each (metric, shape) pair
compiles once and the inner loop is unchanged. The Tanimoto dispatch
branch reproduces the historical op sequence verbatim — int32 union, one
f32 divide, the ``union > 0`` guard — so the default path emits
bit-identical HLO to the pre-metric code (docs/ARCHITECTURE.md §Metric
parameterization).
"""
from __future__ import annotations

import functools
import re
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
DEFAULT_LEN = 1024  # paper: 1024-bit Morgan fingerprint

METRIC_NAMES = ("tanimoto", "dice", "cosine", "tversky")
TVERSKY_SCALE = 256  # tversky weights quantize to this dyadic grid


@dataclass(frozen=True)
class Metric:
    """A bit-vector similarity over the popcount triple (a, b, c).

    * ``tanimoto``  c / (a + b - c)
    * ``dice``      2c / (a + b)
    * ``cosine``    c / sqrt(a * b)
    * ``tversky``   c / (c + alpha*(a - c) + beta*(b - c)) — asymmetric
      when alpha != beta (alpha weighs the query-only bits, beta the
      database-only bits; alpha = beta = 0.5 is Dice, 1/1 is Tanimoto).

    Frozen and hashable: instances are used directly as jit static
    arguments and engine cache-key components. ``alpha``/``beta`` are
    only meaningful for ``tversky`` and are pinned to 0 elsewhere so two
    descriptors of the same metric always compare equal.

    Tversky weights are quantized to the 1/256 grid (``TVERSKY_SCALE``)
    at construction: the score is then a single f32 divide of exact
    integers (``256c / (256c + p_a(a-c) + p_b(b-c))``), which is the only
    formulation that survives XLA:CPU's fast-math FMA contraction — a
    fused mul+add chain evaluates 1-ulp differently inside vs outside jit,
    so a float-weighted denominator cannot satisfy the cross-engine
    bit-parity contract. 0, 0.5 and 1 are on the grid, so the Dice and
    Tanimoto correspondences stay exact.
    """
    name: str = "tanimoto"
    alpha: float = 0.0
    beta: float = 0.0

    def __post_init__(self):
        if self.name not in METRIC_NAMES:
            raise ValueError(
                f"unknown metric {self.name!r}; expected one of {METRIC_NAMES}")
        if self.name != "tversky" and (self.alpha or self.beta):
            raise ValueError(
                f"alpha/beta only apply to tversky, not {self.name!r}")
        if self.name == "tversky":
            if self.alpha < 0 or self.beta < 0:
                raise ValueError("tversky alpha/beta must be >= 0")
            if self.alpha > 16 or self.beta > 16:
                raise ValueError("tversky alpha/beta must be <= 16 "
                                 "(integer-exact arithmetic bound)")
            object.__setattr__(
                self, "alpha", round(self.alpha * TVERSKY_SCALE) / TVERSKY_SCALE)
            object.__setattr__(
                self, "beta", round(self.beta * TVERSKY_SCALE) / TVERSKY_SCALE)

    @property
    def spec(self) -> str:
        """Canonical string form, round-trippable via :func:`resolve_metric`
        (what snapshot meta / ServiceConfig store)."""
        if self.name == "tversky":
            return f"tversky({self.alpha!r},{self.beta!r})"
        return self.name

    @property
    def bounded_below(self) -> bool:
        """A popcount lower bound exists (b >= f(a, Sc) for sim >= Sc)."""
        return self.name != "tversky" or self.alpha > 0

    @property
    def bounded_above(self) -> bool:
        return self.name != "tversky" or self.beta > 0

    @property
    def bounded(self) -> bool:
        """Whether a non-trivial BitBound-style popcount window exists at
        all. Metrics without one (tversky with alpha=beta=0: any overlap
        scores 1) fall back to a full scan — ``scanned`` reflects it."""
        return self.bounded_below or self.bounded_above

    def bound_ratios(self, cutoff: float):
        """Per-metric Eq.2-style popcount window as multipliers of the
        query popcount ``a``: sim(q, d) >= cutoff requires
        ``lo_ratio * a <= b <= hi_ratio * a``. Derivations in
        docs/ARCHITECTURE.md §Metric parameterization; all follow from
        max_c sim at c = min(a, b). Returned as python floats so both the
        float64 host windows and the float32 device masks scale ``a`` by
        the same precomputed constant. Unbounded sides are 0.0 / +inf.
        """
        sc = max(float(cutoff), 1e-6)
        if self.name == "tanimoto":
            # max sim = min/max  =>  b in [a*Sc, a/Sc] (paper Eq. 2)
            return float(cutoff), 1.0 / sc
        if self.name == "dice":
            # max sim = 2*min/(a+b)  =>  b in [a*Sc/(2-Sc), a*(2-Sc)/Sc]
            return float(cutoff) / (2.0 - float(cutoff)), (2.0 - float(cutoff)) / sc
        if self.name == "cosine":
            # max sim = sqrt(min/max)  =>  b in [a*Sc^2, a/Sc^2]
            return float(cutoff) ** 2, 1.0 / sc**2
        # tversky: for b <= a, max sim = b/(b + alpha*(a-b)) >= Sc
        #   <=> b >= a * Sc*alpha / (1 - Sc + Sc*alpha); symmetric above.
        lo = (float(cutoff) * self.alpha
              / max(1.0 - float(cutoff) + float(cutoff) * self.alpha, 1e-9)
              if self.bounded_below else 0.0)
        hi = ((1.0 - float(cutoff) + float(cutoff) * self.beta)
              / max(float(cutoff) * self.beta, 1e-9)
              if self.bounded_above else float("inf"))
        return lo, hi


TANIMOTO = Metric("tanimoto")

_TVERSKY_RE = re.compile(
    r"^tversky\(\s*([0-9.eE+-]+)\s*,\s*([0-9.eE+-]+)\s*\)$")


def resolve_metric(metric) -> Metric:
    """Coerce ``None`` / name string / spec string / Metric to a Metric."""
    if metric is None:
        return TANIMOTO
    if isinstance(metric, Metric):
        return metric
    if isinstance(metric, str):
        m = _TVERSKY_RE.match(metric)
        if m:
            return Metric("tversky", float(m.group(1)), float(m.group(2)))
        if metric == "tversky":
            return Metric("tversky", 0.5, 0.5)   # Dice-weighted default
        return Metric(metric)
    raise TypeError(f"cannot resolve metric from {metric!r}")


def metric_from_counts(metric: Metric, inter, q_cnt, d_cnt):
    """Trace-time metric dispatch on the popcount triple (jnp arrays).

    ``inter``/``q_cnt``/``d_cnt`` are int32 (broadcastable); returns f32.
    The Tanimoto branch is the historical op sequence VERBATIM (int32
    union, one f32 divide, ``union > 0`` guard) — every kernel body and
    engine closure routes through here, and the default path's HLO
    bit-identity depends on this branch never changing shape.
    """
    if metric.name == "tanimoto":
        union = q_cnt + d_cnt - inter
        return jnp.where(union > 0,
                         inter.astype(jnp.float32) / union.astype(jnp.float32),
                         0.0)
    if metric.name == "dice":
        denom = q_cnt + d_cnt
        return jnp.where(denom > 0,
                         (2 * inter).astype(jnp.float32)
                         / denom.astype(jnp.float32),
                         0.0)
    if metric.name == "cosine":
        # sqrt of the exact integer ratio c^2/(a*b), NOT c / sqrt(a*b) or
        # c * rsqrt(a*b): XLA's algebraic simplifier rewrites
        # divide-by-sqrt into multiply-by-rsqrt inside jitted programs but
        # not in eager dispatch (1 ulp nondeterminism across compilation
        # contexts), and the rsqrt form loses the q==d -> exactly-1.0
        # corner. c^2 and a*b are exact ints, so this is two
        # correctly-rounded f32 ops (one divide, one sqrt) with no
        # divide-by-sqrt pattern for the simplifier to touch.
        prod = q_cnt * d_cnt
        ratio = ((inter * inter).astype(jnp.float32)
                 / jnp.maximum(prod, 1).astype(jnp.float32))
        return jnp.where(prod > 0, jnp.sqrt(ratio), 0.0)
    # tversky — exact int32 numerator/denominator on the quantized weight
    # grid, then one f32 divide: no mul+add chain for fast-math to contract
    pa = int(round(metric.alpha * TVERSKY_SCALE))
    pb = int(round(metric.beta * TVERSKY_SCALE))
    num = TVERSKY_SCALE * inter
    den = num + pa * (q_cnt - inter) + pb * (d_cnt - inter)
    return jnp.where(den > 0,
                     num.astype(jnp.float32) / den.astype(jnp.float32), 0.0)


def metric_from_counts_np(metric: Metric, inter, q_cnt, d_cnt) -> np.ndarray:
    """Numpy oracle twin of :func:`metric_from_counts`, returning f32
    values bit-equal to the device f32 path.

    tanimoto/dice divide in float64 and cast (safe: double rounding of
    p/q with q <= 2^12 can only disagree with single f32 rounding at
    exact f32 halfway points, where both round half-even identically);
    tversky is a single f32 divide of exact scaled integers; cosine has a
    second rounding (sqrt of the divide), so it mirrors the f32 op order.
    """
    inter = np.asarray(inter, dtype=np.int64)
    q_cnt = np.asarray(q_cnt, dtype=np.int64)
    d_cnt = np.asarray(d_cnt, dtype=np.int64)
    if metric.name == "tanimoto":
        union = q_cnt + d_cnt - inter
        s = np.where(union > 0, inter / np.maximum(union, 1), 0.0)
        return s.astype(np.float32)
    if metric.name == "dice":
        denom = q_cnt + d_cnt
        s = np.where(denom > 0, 2 * inter / np.maximum(denom, 1), 0.0)
        return s.astype(np.float32)
    if metric.name == "cosine":
        # mirror the device op order exactly: one f32 divide of the exact
        # integer ratio c^2/(a*b), one f32 sqrt — see metric_from_counts
        prod = q_cnt * d_cnt
        ratio = ((inter * inter).astype(np.float32)
                 / np.maximum(prod, 1).astype(np.float32))
        return np.where(prod > 0, np.sqrt(ratio, dtype=np.float32),
                        np.float32(0.0)).astype(np.float32)
    # exact int64 numerator/denominator on the quantized grid, one f32
    # divide — same two correctly-rounded ops as the device path
    pa = int(round(metric.alpha * TVERSKY_SCALE))
    pb = int(round(metric.beta * TVERSKY_SCALE))
    num = TVERSKY_SCALE * inter
    den = num + pa * (q_cnt - inter) + pb * (d_cnt - inter)
    return np.where(den > 0,
                    num.astype(np.float32)
                    / np.maximum(den, 1).astype(np.float32),
                    np.float32(0.0)).astype(np.float32)


def n_words(length: int = DEFAULT_LEN) -> int:
    if length % WORD_BITS != 0:
        raise ValueError(f"fingerprint length {length} must be a multiple of {WORD_BITS}")
    return length // WORD_BITS


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """Pack a (..., L) 0/1 array into (..., L//32) uint32 words (little-endian)."""
    bits = np.asarray(bits, dtype=np.uint8)
    L = bits.shape[-1]
    w = n_words(L)
    shaped = bits.reshape(*bits.shape[:-1], w, WORD_BITS).astype(np.uint32)
    weights = (np.uint32(1) << np.arange(WORD_BITS, dtype=np.uint32))
    return (shaped * weights).sum(axis=-1, dtype=np.uint32)


def unpack_bits(words: np.ndarray, length: int | None = None) -> np.ndarray:
    """Inverse of :func:`pack_bits` -> (..., L) uint8."""
    words = np.asarray(words, dtype=np.uint32)
    L = length or words.shape[-1] * WORD_BITS
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (words[..., :, None] >> shifts) & np.uint32(1)
    return bits.reshape(*words.shape[:-1], words.shape[-1] * WORD_BITS)[..., :L].astype(np.uint8)


def popcount(words: jax.Array) -> jax.Array:
    """Number of set bits per fingerprint: (..., W) uint32 -> (...,) int32."""
    per_word = jax.lax.population_count(words)
    return jnp.sum(per_word.astype(jnp.int32), axis=-1)


@functools.partial(jax.jit, static_argnames=())
def tanimoto(a: jax.Array, b: jax.Array) -> jax.Array:
    """Tanimoto similarity between packed fingerprints (broadcasting).

    a: (..., W) uint32, b: (..., W) uint32 -> (...,) float32 in [0, 1].
    S = |A&B| / |A|B| = c / (cnt_a + cnt_b - c).  Empty/empty pairs -> 0.
    """
    inter = popcount(a & b)
    union = popcount(a) + popcount(b) - inter
    return jnp.where(union > 0, inter.astype(jnp.float32) / union.astype(jnp.float32), 0.0)


def tanimoto_scores(query: jax.Array, db: jax.Array, db_popcount: jax.Array | None = None) -> jax.Array:
    """Scores of one packed query (W,) against a packed DB (N, W) -> (N,) f32.

    ``db_popcount`` may be precomputed (the paper stores DB bit counts once —
    the BitCnt stage runs per query only on the query itself).
    """
    inter = popcount(query[None, :] & db)
    q_cnt = popcount(query)
    d_cnt = popcount(db) if db_popcount is None else db_popcount
    union = q_cnt + d_cnt - inter
    return jnp.where(union > 0, inter.astype(jnp.float32) / union.astype(jnp.float32), 0.0)


def batched_tanimoto_scores(queries: jax.Array, db: jax.Array,
                            db_popcount: jax.Array | None = None) -> jax.Array:
    """(Q, W) x (N, W) -> (Q, N) f32 score matrix (brute-force reference)."""
    if db_popcount is None:
        db_popcount = popcount(db)
    q_cnt = popcount(queries)
    inter = popcount(queries[:, None, :] & db[None, :, :])
    union = q_cnt[:, None] + db_popcount[None, :] - inter
    return jnp.where(union > 0, inter.astype(jnp.float32) / union.astype(jnp.float32), 0.0)


def metric_scores(query: jax.Array, db: jax.Array, metric: Metric = TANIMOTO,
                  db_popcount: jax.Array | None = None) -> jax.Array:
    """Metric-generic twin of :func:`tanimoto_scores` ((W,) x (N, W) -> (N,))."""
    inter = popcount(query[None, :] & db)
    q_cnt = popcount(query)
    d_cnt = popcount(db) if db_popcount is None else db_popcount
    return metric_from_counts(metric, inter, q_cnt, d_cnt)


def batched_metric_scores(queries: jax.Array, db: jax.Array,
                          metric: Metric = TANIMOTO,
                          db_popcount: jax.Array | None = None) -> jax.Array:
    """Metric-generic twin of :func:`batched_tanimoto_scores`.

    The Tanimoto case delegates to the original (same jaxpr) so existing
    default-path callers and the metric path share one trace.
    """
    if metric.name == "tanimoto":
        return batched_tanimoto_scores(queries, db, db_popcount)
    if db_popcount is None:
        db_popcount = popcount(db)
    q_cnt = popcount(queries)
    inter = popcount(queries[:, None, :] & db[None, :, :])
    return metric_from_counts(metric, inter, q_cnt[:, None],
                              db_popcount[None, :])


def batched_metric_scores_np(queries: np.ndarray, db: np.ndarray,
                             metric: Metric = TANIMOTO,
                             db_popcount: np.ndarray | None = None
                             ) -> np.ndarray:
    """Host oracle: (Q, W) x (N, W) -> (Q, N) f32, bit-equal to the device
    f32 paths (see :func:`metric_from_counts_np`)."""
    queries = np.asarray(queries)
    db = np.asarray(db)
    if db_popcount is None:
        db_popcount = np.bitwise_count(db).sum(-1, dtype=np.int64)
    q_cnt = np.bitwise_count(queries).sum(-1, dtype=np.int64)
    inter = np.bitwise_count(queries[:, None, :] & db[None, :, :]).sum(
        -1, dtype=np.int64)
    return metric_from_counts_np(metric, inter, q_cnt[:, None],
                                 np.asarray(db_popcount, np.int64)[None, :])
