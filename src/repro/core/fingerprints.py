"""Packed binary molecular fingerprints and Tanimoto similarity.

Fingerprints are L-bit binary vectors (paper: L=1024, Morgan radius-2).
We store them packed little-endian into ``uint32`` words, shape (..., L//32),
so a database of N molecules is an ``(N, 32)`` uint32 array for L=1024.
All similarity math runs on packed words via ``lax.population_count`` —
the TPU-native analogue of the paper's BitCnt LUT tree (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
DEFAULT_LEN = 1024  # paper: 1024-bit Morgan fingerprint


def n_words(length: int = DEFAULT_LEN) -> int:
    if length % WORD_BITS != 0:
        raise ValueError(f"fingerprint length {length} must be a multiple of {WORD_BITS}")
    return length // WORD_BITS


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """Pack a (..., L) 0/1 array into (..., L//32) uint32 words (little-endian)."""
    bits = np.asarray(bits, dtype=np.uint8)
    L = bits.shape[-1]
    w = n_words(L)
    shaped = bits.reshape(*bits.shape[:-1], w, WORD_BITS).astype(np.uint32)
    weights = (np.uint32(1) << np.arange(WORD_BITS, dtype=np.uint32))
    return (shaped * weights).sum(axis=-1, dtype=np.uint32)


def unpack_bits(words: np.ndarray, length: int | None = None) -> np.ndarray:
    """Inverse of :func:`pack_bits` -> (..., L) uint8."""
    words = np.asarray(words, dtype=np.uint32)
    L = length or words.shape[-1] * WORD_BITS
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (words[..., :, None] >> shifts) & np.uint32(1)
    return bits.reshape(*words.shape[:-1], words.shape[-1] * WORD_BITS)[..., :L].astype(np.uint8)


def popcount(words: jax.Array) -> jax.Array:
    """Number of set bits per fingerprint: (..., W) uint32 -> (...,) int32."""
    per_word = jax.lax.population_count(words)
    return jnp.sum(per_word.astype(jnp.int32), axis=-1)


@functools.partial(jax.jit, static_argnames=())
def tanimoto(a: jax.Array, b: jax.Array) -> jax.Array:
    """Tanimoto similarity between packed fingerprints (broadcasting).

    a: (..., W) uint32, b: (..., W) uint32 -> (...,) float32 in [0, 1].
    S = |A&B| / |A|B| = c / (cnt_a + cnt_b - c).  Empty/empty pairs -> 0.
    """
    inter = popcount(a & b)
    union = popcount(a) + popcount(b) - inter
    return jnp.where(union > 0, inter.astype(jnp.float32) / union.astype(jnp.float32), 0.0)


def tanimoto_scores(query: jax.Array, db: jax.Array, db_popcount: jax.Array | None = None) -> jax.Array:
    """Scores of one packed query (W,) against a packed DB (N, W) -> (N,) f32.

    ``db_popcount`` may be precomputed (the paper stores DB bit counts once —
    the BitCnt stage runs per query only on the query itself).
    """
    inter = popcount(query[None, :] & db)
    q_cnt = popcount(query)
    d_cnt = popcount(db) if db_popcount is None else db_popcount
    union = q_cnt + d_cnt - inter
    return jnp.where(union > 0, inter.astype(jnp.float32) / union.astype(jnp.float32), 0.0)


def batched_tanimoto_scores(queries: jax.Array, db: jax.Array,
                            db_popcount: jax.Array | None = None) -> jax.Array:
    """(Q, W) x (N, W) -> (Q, N) f32 score matrix (brute-force reference)."""
    if db_popcount is None:
        db_popcount = popcount(db)
    q_cnt = popcount(queries)
    inter = popcount(queries[:, None, :] & db[None, :, :])
    union = q_cnt[:, None] + db_popcount[None, :] - inter
    return jnp.where(union > 0, inter.astype(jnp.float32) / union.astype(jnp.float32), 0.0)
