"""BitBound pruning (Swamidass & Baldi) — Eq. 2 of the paper.

The database is sorted by popcount once at index-build time. For a query with
popcount ``a`` and similarity cutoff ``Sc``, only candidates whose popcount
``b`` satisfies

    a * Sc  <=  b  <=  a / Sc                                   (Eq. 2)

can have Tanimoto(query, cand) >= Sc (because S <= min(a,b)/max(a,b)).
The contiguous popcount-sorted range is located with two searchsorted ops and
the scan is restricted to it.  The paper models the pruned fraction with a
Gaussian fit of the popcount distribution (Eq. 3) — reproduced in
``gaussian_model`` / ``expected_speedup`` and benchmarked in
``benchmarks/bitbound_speedup.py`` (Fig. 2).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .fingerprints import Metric, TANIMOTO, popcount


@dataclass
class BitBoundIndex:
    """Popcount-sorted fingerprint database."""
    db: jax.Array            # (N, W) uint32, sorted by popcount ascending
    counts: jax.Array        # (N,) int32 popcounts, ascending
    order: jax.Array         # (N,) int32 — original index of each sorted row
    # Gaussian fit of the popcount distribution (paper Eq. 3)
    mu: float
    sigma: float

    @property
    def n(self) -> int:
        return self.db.shape[0]


def build_index(db: jax.Array) -> BitBoundIndex:
    counts = np.asarray(popcount(db))
    order = np.argsort(counts, kind="stable").astype(np.int32)
    db_sorted = jnp.asarray(np.asarray(db)[order])
    counts_sorted = jnp.asarray(counts[order].astype(np.int32))
    return BitBoundIndex(db=db_sorted, counts=counts_sorted,
                         order=jnp.asarray(order),
                         mu=float(counts.mean()), sigma=float(counts.std()))


def bound_range(index: BitBoundIndex, query_count: jax.Array, cutoff: float,
                metric: Metric = TANIMOTO):
    """Per-metric candidate range [lo, hi) in the popcount-sorted database
    (Tanimoto: the paper's Eq. 2)."""
    a = query_count.astype(jnp.float32)
    if metric.name == "tanimoto":
        lo_cnt = jnp.ceil(a * cutoff)
        hi_cnt = jnp.floor(a / jnp.maximum(cutoff, 1e-6))
    else:
        lo_r, hi_r = metric.bound_ratios(cutoff)
        lo_cnt = jnp.ceil(a * lo_r) if metric.bounded_below else jnp.zeros_like(a)
        hi_cnt = (jnp.minimum(jnp.floor(a * hi_r), 2.0**30)
                  if metric.bounded_above else jnp.full_like(a, 2.0**30))
    lo = jnp.searchsorted(index.counts, lo_cnt.astype(jnp.int32), side="left")
    hi = jnp.searchsorted(index.counts, hi_cnt.astype(jnp.int32), side="right")
    return lo, hi


def bound_counts_np(query_counts: np.ndarray, cutoff: float,
                    metric: Metric = TANIMOTO):
    """Per-metric popcount bounds in float64 (Tanimoto: Eq. 2
    ``[ceil(a*Sc), floor(a/Sc)]``; others via ``Metric.bound_ratios``).

    THE host-side bound formula: :func:`bound_range_np` (main-segment
    windows) and the engines' delta-segment masks all call this one helper —
    the insert-then-rebuild bit-parity contract requires the main window and
    the delta mask to agree on every boundary popcount, so the clamp and
    float width must never diverge between call sites. Unbounded sides come
    back as 0 / +inf (searchsorted treats them as full-scan windows, and
    ``scanned`` reflects the full scan).
    """
    a = np.asarray(query_counts, dtype=np.float64)
    if metric.name == "tanimoto":
        lo_cnt = np.ceil(a * cutoff)
        hi_cnt = np.floor(a / max(cutoff, 1e-6))
        return lo_cnt, hi_cnt
    lo_r, hi_r = metric.bound_ratios(cutoff)
    lo_cnt = np.ceil(a * lo_r) if metric.bounded_below else np.zeros_like(a)
    hi_cnt = (np.floor(a * hi_r) if metric.bounded_above
              else np.full_like(a, np.inf))
    return lo_cnt, hi_cnt


def bound_range_np(counts_sorted: np.ndarray, query_counts: np.ndarray,
                   cutoff: float, metric: Metric = TANIMOTO):
    """Host-side batched per-metric windows [lo, hi) for a whole query batch.

    Numpy analogue of :func:`bound_range`; the engine uses it to size the
    static kernel grid (a Python int) before dispatching to device. Note the
    bound is evaluated in float64 here vs float32 on device, so for popcounts
    landing exactly on the a/Sc boundary the two can differ by one count
    value — both are valid Eq.2 windows, but don't cross-validate them
    expecting bit-equality.
    """
    lo_cnt, hi_cnt = bound_counts_np(query_counts, cutoff, metric)
    lo = np.searchsorted(counts_sorted, lo_cnt, side="left")
    hi = np.searchsorted(counts_sorted, hi_cnt, side="right")
    return lo.astype(np.int64), hi.astype(np.int64)


def bucket_tiles(n_tiles: int, total_tiles: int) -> int:
    """Round a tile-window size up to the next power of two (clamped to the
    whole DB) — the engine compiles one kernel per bucket, so the number of
    distinct compilations is O(log total_tiles) regardless of query mix."""
    n_tiles = max(int(n_tiles), 1)
    b = 1 << (n_tiles - 1).bit_length()
    return min(b, max(int(total_tiles), 1))


def aligned_range(lo, hi, tile: int, n: int):
    """Round the candidate range outward to tile boundaries (the engine scans
    whole HBM tiles; partial tiles are masked inside the kernel)."""
    lo_t = (lo // tile) * tile
    hi_t = jnp.minimum(((hi + tile - 1) // tile) * tile, n)
    return lo_t, hi_t


# --- analytical model (paper Fig. 2) ---------------------------------------

def gaussian_model(x: np.ndarray, mu: float, sigma: float) -> np.ndarray:
    """Paper Eq. 3 — popcount density model."""
    return np.exp(-((x - mu) ** 2) / (2 * sigma**2)) / np.sqrt(2 * np.pi * sigma**2)


def expected_search_fraction(mu: float, sigma: float, cutoff: float,
                             grid: int = 4096, max_bits: int = 1024) -> float:
    """Expected fraction of the DB scanned per query under the Gaussian model:
    E_a~N [ Phi(a/Sc) - Phi(a*Sc) ].  Speedup = 1 / fraction (Fig. 2d)."""
    from math import erf, sqrt

    def phi(x):
        return 0.5 * (1.0 + erf((x - mu) / (sigma * sqrt(2.0))))

    xs = np.linspace(max(0.0, mu - 5 * sigma), min(max_bits, mu + 5 * sigma), grid)
    dens = gaussian_model(xs, mu, sigma)
    dens /= dens.sum()
    frac = sum(d * (phi(a / max(cutoff, 1e-6)) - phi(a * cutoff)) for a, d in zip(xs, dens))
    return float(max(min(frac, 1.0), 1e-9))


def expected_speedup(mu: float, sigma: float, cutoff: float) -> float:
    return 1.0 / expected_search_fraction(mu, sigma, cutoff)
